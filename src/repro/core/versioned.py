"""Versioned data sets and snapshots — paper §2.3.1 (Fig 3).

Every data item carries versions ``(epoch, version)``; a mutation creates a
new version. A snapshot is resolved with the paper's rule::

    snapshot(v) = { d(i_v) },   i_v = max { v' <= v }

Two implementations share the rule:

* :class:`VersionedStore` — host-side multi-version KV store (control plane:
  checkpoints, schemas, replica directory entries).
* :func:`resolve_versions` / :class:`VersionedArray` — JAX data plane: a
  vectorized ``searchsorted`` resolves whole columns of versioned items at
  once (used by the dynamic graph store for snapshot masks).
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Any, Iterable, Optional

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True, order=True)
class Version:
    """Paper Fig 3(a): epoch identifier + version number within the epoch."""
    epoch: int
    number: int

    def pack(self) -> int:
        return (self.epoch << 32) | self.number

    @staticmethod
    def unpack(packed: int) -> "Version":
        return Version(packed >> 32, packed & 0xFFFFFFFF)


ZERO = Version(0, 0)

# Data-plane (JAX) packing: int32-safe (x64 is disabled in JAX by default).
# Host-side control plane uses the full 64-bit pack().
PACK_BITS = 20


def pack32(v: Version) -> int:
    assert v.epoch < (1 << (31 - PACK_BITS)) and v.number < (1 << PACK_BITS), v
    return (v.epoch << PACK_BITS) | v.number


class VersionedStore:
    """Multi-version key-value items (paper Fig 3(b))."""

    def __init__(self):
        # key -> (sorted list of packed versions, list of values)
        self._items: dict[Any, tuple[list[int], list[Any]]] = {}

    def put(self, key, version: Version, value) -> None:
        vs, vals = self._items.setdefault(key, ([], []))
        packed = version.pack()
        idx = bisect.bisect_left(vs, packed)
        if idx < len(vs) and vs[idx] == packed:
            raise ValueError(f"version {version} of {key!r} already written "
                             "(versions are immutable)")
        vs.insert(idx, packed)
        vals.insert(idx, value)

    def get(self, key, version: Optional[Version] = None):
        """Paper's snapshot rule: value at max version <= requested."""
        if key not in self._items:
            raise KeyError(key)
        vs, vals = self._items[key]
        if version is None:
            return vals[-1]
        idx = bisect.bisect_right(vs, version.pack()) - 1
        if idx < 0:
            raise KeyError(f"{key!r} has no version <= {version}")
        return vals[idx]

    def versions(self, key) -> list[Version]:
        return [Version.unpack(p) for p in self._items.get(key, ([], []))[0]]

    def keys(self) -> Iterable:
        return self._items.keys()

    def snapshot(self, version: Version) -> dict:
        """Materialize {key: d(i_v)} for all keys with a version <= v."""
        out = {}
        for key in self._items:
            try:
                out[key] = self.get(key, version)
            except KeyError:
                pass
        return out

    def gc_below(self, version: Version) -> int:
        """Collect obsolete versions: keep, per key, only the newest version
        <= v (still addressable by snapshot(v)) plus everything > v.
        Returns number of dropped versions (paper §2.2 'obsolete replicas')."""
        dropped = 0
        packed = version.pack()
        for key, (vs, vals) in self._items.items():
            idx = bisect.bisect_right(vs, packed) - 1
            if idx > 0:
                del vs[:idx]
                del vals[:idx]
                dropped += idx
        return dropped


def resolve_versions(item_versions, query_version):
    """Vectorized snapshot rule over a column of packed versions.

    item_versions: (N, K) packed versions per item, sorted ascending along K,
    padded with ``jnp.iinfo(int64).max`` for unused slots.
    Returns (N,) index i_v into K of max version <= query, or -1 if none.
    """
    item_versions = jnp.asarray(item_versions)
    q = jnp.asarray(query_version, item_versions.dtype)
    # searchsorted per row: count of versions <= q, minus one
    idx = jnp.sum(item_versions <= q, axis=-1) - 1
    return idx


class VersionedArray:
    """A fixed-capacity multi-version array column (JAX data plane).

    values: (N, K) — K version slots per item; versions: (N, K) packed,
    ascending, MAX-padded. Snapshot read = one vectorized resolve + gather.
    """

    MAXV = np.iinfo(np.int32).max

    def __init__(self, n_items: int, capacity: int, dtype=jnp.float32):
        self.values = jnp.zeros((n_items, capacity), dtype)
        self.versions = jnp.full((n_items, capacity), self.MAXV, jnp.int32)
        self.fill = jnp.zeros((n_items,), jnp.int32)

    def write(self, item_ids, version: Version, new_values):
        """Append a new version for the given items (one mutation batch)."""
        item_ids = jnp.asarray(item_ids)
        slots = self.fill[item_ids]
        self.values = self.values.at[item_ids, slots].set(new_values)
        self.versions = self.versions.at[item_ids, slots].set(pack32(version))
        self.fill = self.fill.at[item_ids].add(1)
        return self

    def read_snapshot(self, version: Version, default=0):
        idx = resolve_versions(self.versions, pack32(version))
        safe = jnp.maximum(idx, 0)
        vals = jnp.take_along_axis(self.values, safe[:, None], axis=1)[:, 0]
        return jnp.where(idx >= 0, vals, default)
