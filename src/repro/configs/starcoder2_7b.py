"""StarCoder2-7B [arXiv:2402.19173]: 32L, d_model=4608, 36 heads GQA kv=4,
d_ff=18432 plain-GELU MLP with bias, vocab 49152, RoPE, LayerNorm."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="starcoder2-7b",
    family="dense",
    num_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    pattern=("attn",),
    ffn="gelu_mlp",
    norm="ln",
    qkv_bias=True,
    mlp_bias=True,
    rope=True,
    rope_theta=1_000_000.0,
    subquadratic=False,
))
