"""SP002 clean twin: the pool receives shard-owned bound methods; the
serial seams run on the calling thread after the barrier."""


class Plane:
    def __init__(self):
        self.results = []
        self.frontier = -1

    def seal_epoch(self, pool, nodes, epoch):
        futures = [pool.submit(n.seal_epoch, epoch) for n in nodes]
        errors = [f.exception() for f in futures]        # barrier
        for err in errors:
            if err is not None:
                raise err
        self.frontier = epoch                # calling thread: fine
        self.results.append(epoch)           # calling thread: fine
        return futures
