"""Qwen2.5-14B [hf:Qwen/Qwen2.5 family]: 48L, d_model=5120, 40 heads GQA kv=8,
d_ff=13824, vocab 152064, QKV bias, RoPE theta 1e6, SwiGLU, RMSNorm."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    num_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=152064,
    pattern=("attn",),
    ffn="swiglu",
    norm="rms",
    qkv_bias=True,
    rope=True,
    rope_theta=1_000_000.0,
    subquadratic=False,
))
