"""Replica-coherence data management — paper §2.2.

The paper's idea: partitioning is driven by *two* factors (load balance and
communication), replicas are created/migrated from observed access patterns,
writes keep replicas coherent, and obsolete replicas are collected.

Implementation (TPU adaptation per DESIGN.md §2): the *protocol* lives on the
control plane (this module — ownership, mirrors, invalidate-on-write,
access-stats-driven placement, GC); the *policy* output also drives the
ahead-of-time sharding of tensors in the compiled programs
(:class:`SharedTensorPolicy`, consumed by ``launch/sharding.py``).
"""
from __future__ import annotations

import dataclasses
from collections import Counter, defaultdict
from typing import Any, Optional

import numpy as np

from repro.core.versioned import Version, VersionedStore


# ----------------------------------------------------------------- coherence
@dataclasses.dataclass
class ReplicaMeta:
    owner: int
    mirrors: set[int] = dataclasses.field(default_factory=set)
    last_write: Version = Version(0, 0)
    # mirror -> version it last pulled (invalidate-on-write coherence)
    mirror_version: dict[int, Version] = dataclasses.field(default_factory=dict)
    last_used: dict[int, int] = dataclasses.field(default_factory=dict)


class ReplicaManager:
    """Owner/mirror coherence with access-stats-driven placement.

    Protocol:
      * every item has one *owner* node; writes commit at the owner and
        bump the item's version (a Paxos write in the real system; the
        single-writer discipline gives the same serializable order here);
      * mirrors serve reads; a write *invalidates* mirrors (they re-pull on
        next read -> coherence: a mirror never serves a value older than the
        invalidation);
      * ``rebalance()`` = the paper's scheduler: creates mirrors where remote
        read traffic is high, migrates ownership toward the heaviest writer,
        and keeps partitions load-balanced;
      * ``collect_obsolete()`` GCs mirrors unused for ``ttl`` rounds.
    """

    def __init__(self, n_nodes: int, *, mirror_threshold: int = 8,
                 ttl: int = 3, alpha_balance: float = 1.0,
                 beta_comm: float = 1.0):
        self.n_nodes = n_nodes
        self.meta: dict[Any, ReplicaMeta] = {}
        self.store = VersionedStore()          # committed (owner) versions
        self.remote_reads: Counter = Counter() # (node, item) -> count
        self.local_hits = 0
        self.remote_misses = 0
        self.invalidations = 0
        self.round = 0
        self.mirror_threshold = mirror_threshold
        self.ttl = ttl
        self.alpha = alpha_balance
        self.beta = beta_comm

    # -- placement ----------------------------------------------------------
    def add_item(self, item, owner: Optional[int] = None,
                 version: Optional[Version] = None, value: Any = None):
        version = Version(0, 0) if version is None else version
        owner = hash(item) % self.n_nodes if owner is None else owner
        self.meta[item] = ReplicaMeta(owner=owner, last_write=version)
        self.store.put(item, version, value)

    def holds(self, node: int, item) -> bool:
        m = self.meta[item]
        return node == m.owner or node in m.mirrors

    # -- protocol ------------------------------------------------------------
    def read(self, node: int, item, version: Optional[Version] = None):
        m = self.meta[item]
        m.last_used[node] = self.round
        if node == m.owner:
            self.local_hits += 1
            return self.store.get(item, version)
        if node in m.mirrors:
            pulled = m.mirror_version.get(node, Version(0, 0))
            if pulled >= m.last_write:
                self.local_hits += 1
                return self.store.get(item, version)
            # invalidated -> re-pull from owner (counts as one remote fetch)
            self.remote_misses += 1
            m.mirror_version[node] = m.last_write
            return self.store.get(item, version)
        self.remote_misses += 1
        self.remote_reads[(node, item)] += 1
        return self.store.get(item, version)

    def write(self, node: int, item, version: Version, value) -> None:
        m = self.meta[item]
        if node != m.owner:
            # forwarded to owner (single-writer serialization)
            self.remote_reads[(node, item)] += 1
        if version <= m.last_write:
            raise ValueError(f"stale write to {item!r}: {version} <= {m.last_write}")
        self.store.put(item, version, value)
        m.last_write = version
        # coherence: invalidate all mirrors
        self.invalidations += len(m.mirrors)

    # -- scheduler -----------------------------------------------------------
    def node_loads(self) -> list[int]:
        loads = [0] * self.n_nodes
        for m in self.meta.values():
            loads[m.owner] += 1
        return loads

    def cost(self) -> float:
        """Dynamic-equilibrium objective: alpha * imbalance + beta * traffic."""
        loads = self.node_loads()
        mean = sum(loads) / max(len(loads), 1)
        imbalance = sum((l - mean) ** 2 for l in loads)
        traffic = sum(self.remote_reads.values())
        return self.alpha * imbalance + self.beta * traffic

    def rebalance(self) -> dict:
        """One scheduler round: mirror hot remote items; migrate ownership to
        the dominant accessor when it will not break balance."""
        self.round += 1
        created, migrated = 0, 0
        loads = self.node_loads()
        mean = sum(loads) / max(len(loads), 1)
        per_item: dict[Any, Counter] = defaultdict(Counter)
        for (node, item), cnt in self.remote_reads.items():
            per_item[item][node] += cnt
        for item, counts in per_item.items():
            m = self.meta[item]
            node, cnt = counts.most_common(1)[0]
            if cnt >= self.mirror_threshold and node not in m.mirrors:
                # paper: 'this replica should be swapped to the requester'
                if loads[node] <= mean * 1.5:
                    m.owner, old = node, m.owner
                    m.mirrors.add(old)
                    m.mirror_version[old] = m.last_write
                    loads[node] += 1
                    loads[old] -= 1
                    migrated += 1
                else:
                    m.mirrors.add(node)
                    m.mirror_version[node] = m.last_write
                    created += 1
        self.remote_reads.clear()
        collected = self.collect_obsolete()
        return {"mirrors_created": created, "owners_migrated": migrated,
                "mirrors_collected": collected}

    def collect_obsolete(self) -> int:
        """GC mirrors unused for ttl rounds (paper: 'collect the obsolete
        replicas')."""
        collected = 0
        for m in self.meta.values():
            dead = {n for n in m.mirrors
                    if self.round - m.last_used.get(n, -10**9) > self.ttl}
            m.mirrors -= dead
            for n in dead:
                m.mirror_version.pop(n, None)
            collected += len(dead)
        return collected

    def stats(self) -> dict:
        return {
            "local_hits": self.local_hits,
            "remote_misses": self.remote_misses,
            "hit_rate": self.local_hits / max(self.local_hits + self.remote_misses, 1),
            "invalidations": self.invalidations,
            "cost": self.cost(),
        }


# ------------------------------------------------------- shard-range scheduler
@dataclasses.dataclass(frozen=True)
class SplitDecision:
    """One planner verdict: split ``shard``'s key range.

    ``load`` is the shard's observed load (mutations + weighted query
    touches, EWMA over recent epochs), ``mean_load`` the fleet mean at
    decision time; ``reason`` is a human-readable audit line surfaced in
    re-sharding summaries and server stats.
    """
    shard: int
    load: float
    mean_load: float
    reason: str


@dataclasses.dataclass(frozen=True)
class MergeDecision:
    """One planner verdict: fold ``removed`` back into its split sibling
    ``survivor`` (the inverse of :class:`SplitDecision`). ``load`` is the
    pair's combined observed load, ``mean_load`` the live-fleet mean at
    decision time."""
    survivor: int
    removed: int
    load: float
    mean_load: float
    reason: str


class ShardPlanner:
    """Access-pattern-driven re-sharding policy — the paper's scheduler rule
    (:meth:`ReplicaManager.rebalance`) lifted from per-item replicas to
    whole shard key ranges.

    ``rebalance`` mirrors/migrates single hot *items* from observed access
    counts; a graph shard is instead a hash *range* of destination keys, so
    the planner's unit of action is a range split: when one shard's
    observed load (the same dynamic-equilibrium imbalance term as
    :meth:`ReplicaManager.cost`) exceeds ``imbalance_threshold`` times the
    fleet mean, it proposes splitting that shard's range in half
    (consistent-hash style — only the migrating half moves). The mechanism
    (plan versioning, epoch-aligned migration) lives in
    ``repro.graph.sharded``; this class is pure policy and holds no graph
    state, so it is trivially testable and swappable.

    The inverse lever, leaf coarsening, uses the same ledger: when a
    mergeable sibling pair's COMBINED load falls below
    ``merge_threshold`` times the live-fleet mean, :meth:`propose_merge`
    folds the pair back into one shard — reclaiming fan-out headroom the
    earlier split spent (which sibling pairs are legal comes from the
    routing plan's leaf tree, passed in as ``pairs``; policy stays
    graph-state-free).

    Guard rails: never propose beyond ``max_shards``; require
    ``min_epochs`` of observation since the last split (cooldown — stats
    reset on every split, so ``epochs_observed`` restarts) and ``min_load``
    total observed load (don't react to noise on an idle store).
    """

    def __init__(self, *, imbalance_threshold: float = 1.5,
                 min_load: float = 512.0, min_epochs: int = 2,
                 max_shards: int = 16, merge_threshold: float = 0.35):
        if imbalance_threshold <= 1.0:
            raise ValueError("imbalance_threshold must exceed 1.0 "
                             "(1.0 means perfectly balanced)")
        if not 0.0 < merge_threshold < 1.0:
            raise ValueError("merge_threshold must sit in (0, 1) "
                             "(a fraction of the fleet-mean load)")
        self.imbalance_threshold = imbalance_threshold
        self.min_load = min_load
        self.min_epochs = min_epochs
        self.max_shards = max_shards
        self.merge_threshold = merge_threshold

    @staticmethod
    def _live_mask(n: int, live) -> list[bool]:
        if live is None:
            return [True] * n
        mask = [bool(x) for x in live]
        if len(mask) != n:
            raise ValueError(f"live mask has {len(mask)} entries for "
                             f"{n} shards")
        return mask

    def propose(self, loads, *, epochs_observed: int,
                live=None) -> Optional[SplitDecision]:
        """One scheduler round: return the split to perform, or None.

        ``loads`` is the per-shard load vector (any sequence of floats);
        ``epochs_observed`` is how many sealed epochs the vector spans.
        ``live`` optionally masks out retired (merged-away) shards: they
        are never proposed and their permanently-zero loads are excluded
        from the mean. Pure function of its inputs — safe to call every
        epoch.
        """
        loads = [float(x) for x in loads]
        mask = self._live_mask(len(loads), live)
        alive = [i for i in range(len(loads)) if mask[i]]
        if len(alive) >= self.max_shards:
            return None
        if epochs_observed < self.min_epochs:
            return None
        total = sum(loads[i] for i in alive)
        if total < self.min_load:
            return None
        mean = total / len(alive)
        hot = max(alive, key=lambda i: loads[i])
        if loads[hot] <= self.imbalance_threshold * mean:
            return None
        return SplitDecision(
            shard=hot, load=loads[hot], mean_load=mean,
            reason=(f"shard {hot} load {loads[hot]:.0f} > "
                    f"{self.imbalance_threshold:.2f}x mean {mean:.1f} "
                    f"over {epochs_observed} epochs"))

    def propose_merge(self, loads, *, epochs_observed: int,
                      pairs, live=None) -> Optional[MergeDecision]:
        """Return the sibling merge to perform, or None.

        ``pairs`` is the legal ``(survivor, removed)`` sibling pairs from
        the routing plan (``RoutingPlan.mergeable_pairs()``). Picks the
        coldest pair, and only if its combined load is below
        ``merge_threshold`` x the live-fleet mean — the deliberate gap
        between that and ``imbalance_threshold`` is the hysteresis band
        that keeps a borderline shard from split/merge flapping. Same
        ``min_epochs`` / ``min_load`` noise guards as :meth:`propose`
        (an idle store looks uniformly cold; that is no reason to
        coarsen it)."""
        loads = [float(x) for x in loads]
        mask = self._live_mask(len(loads), live)
        alive = [i for i in range(len(loads)) if mask[i]]
        if epochs_observed < self.min_epochs or not alive:
            return None
        total = sum(loads[i] for i in alive)
        if total < self.min_load:
            return None
        mean = total / len(alive)
        best = None
        for survivor, removed in pairs:
            if not (mask[survivor] and mask[removed]):
                continue
            pair_load = loads[survivor] + loads[removed]
            if best is None or pair_load < best[0]:
                best = (pair_load, survivor, removed)
        if best is None:
            return None
        pair_load, survivor, removed = best
        if pair_load >= self.merge_threshold * mean:
            return None
        return MergeDecision(
            survivor=survivor, removed=removed, load=pair_load,
            mean_load=mean,
            reason=(f"siblings ({survivor}, {removed}) combined load "
                    f"{pair_load:.0f} < {self.merge_threshold:.2f}x mean "
                    f"{mean:.1f} over {epochs_observed} epochs"))


class MirrorPlanner:
    """Hot-vertex nomination policy for the replica plane: pick which
    vertices get their adjacency mirrored at the next publish.

    Deliberately a pure function of the access ledger's per-vertex heat
    vector — stable top-k (ties broken by vertex id), filtered by
    ``min_heat``, returned as sorted ids. No hysteresis state, so the
    resulting :class:`~repro.graph.sharded.ReplicaPlan` — and therefore
    replica-first routing — is deterministic given (plan, ledger), which
    the property tests assert.
    """

    def __init__(self, *, mirror_k: int = 64, min_heat: float = 1.0):
        if mirror_k < 0:
            raise ValueError("mirror_k must be >= 0")
        self.mirror_k = mirror_k
        self.min_heat = min_heat

    def nominate(self, heat) -> np.ndarray:
        """Sorted int64 ids of the up-to-``mirror_k`` hottest vertices
        with heat >= ``min_heat``."""
        h = np.asarray(heat, np.float64).reshape(-1)
        if not self.mirror_k or not h.size:
            return np.zeros(0, np.int64)
        # stable argsort on -heat: equal heat resolves to the lower id
        order = np.argsort(-h, kind="stable")[:self.mirror_k]
        hot = order[h[order] >= self.min_heat]
        return np.sort(hot.astype(np.int64))


# ----------------------------------------------------- LM-side sharding policy
@dataclasses.dataclass
class TensorAccess:
    """Access statistics for one tensor in a compiled program."""
    name: str
    bytes_size: int            # full (unsharded) tensor bytes
    gather_bytes_per_step: int # collective traffic if sharded (from HLO)
    current: str               # "sharded" | "replicated"


class SharedTensorPolicy:
    """Replica-coherence policy for AOT-compiled programs: choose which
    tensors to replicate (mirror on every chip) vs shard, under a memory
    budget — the knapsack the paper's scheduler solves reactively, solved
    ahead-of-time from measured access patterns (HLO collective bytes)."""

    def __init__(self, hbm_budget_bytes: int):
        self.budget = hbm_budget_bytes

    def propose(self, tensors: list[TensorAccess], n_chips: int) -> dict:
        """Greedy: replicate tensors with the best traffic-saved per byte."""
        decisions = {}
        spent = 0
        ranked = sorted(
            (t for t in tensors if t.current == "sharded"),
            key=lambda t: t.gather_bytes_per_step / max(t.bytes_size, 1),
            reverse=True)
        for t in ranked:
            extra = t.bytes_size - t.bytes_size // n_chips
            if t.gather_bytes_per_step > t.bytes_size // n_chips and \
                    spent + extra <= self.budget:
                decisions[t.name] = "replicate"
                spent += extra
            else:
                decisions[t.name] = "keep-sharded"
        return {"decisions": decisions, "extra_bytes": spent}
