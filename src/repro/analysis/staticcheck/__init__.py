"""reprolint: invariant-aware static analysis for this repo.

Importing this package registers all four checker families (lock
discipline RL0xx, jit trace-stability TS0xx, int32 stamp hygiene SH0xx,
seal-plane disjointness SP0xx) with the core registry; ``RULES`` and
``check_source``/``check_paths`` are then ready to use. The CLI lives in
``scripts/run_staticcheck.py``.
"""
from repro.analysis.staticcheck import (lockcheck, sealcheck,  # noqa: F401
                                        stampcheck, tracecheck)
from repro.analysis.staticcheck.core import (CHECKERS, RULES, Finding,
                                             check_file, check_paths,
                                             check_source, gate,
                                             load_baseline, to_json)

__all__ = [
    "CHECKERS", "RULES", "Finding", "check_file", "check_paths",
    "check_source", "gate", "load_baseline", "to_json",
    "lockcheck", "tracecheck", "stampcheck", "sealcheck",
]
