"""SH001 clean twin: stamps cross the 64/32 boundary only through the
sanctioned bridges (pack32_checked for stores, pack32_clamped for
queries, the int32 MAXV sentinel for never-deleted)."""
import numpy as np

from repro.core.versioned import pack32_checked, pack32_clamped

MAXV = np.iinfo(np.int32).max


class Store:
    def __init__(self, e_max):
        self.created = np.zeros(e_max, np.int32)
        self.deleted = np.zeros(e_max, np.int32)
        self.n_edges = 0

    def live_mask(self, version):
        q = pack32_clamped(version)
        return self.created[: self.n_edges] <= q

    def mark(self, rows, version):
        self.deleted[rows] = pack32_checked(version)

    def revive(self, rows):
        self.deleted[rows] = MAXV
