"""INGEST-WHILE-QUERYING DEMO — the paper's integrated online/offline
claim, live.

A background thread streams mutation epochs into a 4-shard
``ShardedDynamicGraph`` (no-wait dispatch, per-shard seals, global
frontier). The foreground thread is a query client hammering the
``GraphQueryServer`` the whole time: every answered window is served
strictly from the newest frontier-sealed snapshot — a moving target while
the stream is live — and each answer is checked byte-for-byte against a
single-store replay at the SAME version after the fact.

    PYTHONPATH=src python examples/serve_graph_live.py          # full demo
    PYTHONPATH=src python examples/serve_graph_live.py --smoke  # CI-sized
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.graph import compute as gc
from repro.graph.dyngraph import DynamicGraph, synthesize_churn_stream
from repro.graph.query import (DegreeTopK, KHop, PageRankQuery, Reachability)
from repro.graph.sharded import ShardedDynamicGraph
from repro.launch.serve_graph import GraphQueryServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config for CI")
    args = ap.parse_args()
    n = 300 if args.smoke else 2_000
    epochs = 6 if args.smoke else 10
    adds = 150 if args.smoke else 800

    batches = synthesize_churn_stream(n, epochs, adds, seed=1,
                                      delete_frac=0.2)
    e_max = sum(len(b.add_src) for b in batches) + 16
    sg = ShardedDynamicGraph(4, n, e_max)
    server = GraphQueryServer(sg, prewarm_pagerank=True, tol=1e-4,
                              max_iter=200)

    print(f"== streaming {epochs} epochs into 4 shards while querying ==")
    # pace the stream so epochs keep sealing while the client queries
    # (first windows also pay one-off jit compilation)
    thread = server.start_background_ingest(iter(batches), delay_s=0.8)

    rng = np.random.default_rng(7)
    answered = []
    windows = 0
    while thread.is_alive() or not answered:
        for _ in range(4):
            server.submit(KHop(int(rng.integers(0, n)), k=2))
        server.submit(Reachability(int(rng.integers(0, n)),
                                   int(rng.integers(0, n)), max_hops=6))
        server.submit(DegreeTopK(5))
        server.submit(PageRankQuery(top_k=5))
        try:
            results = server.flush()
        except RuntimeError:          # nothing globally sealed yet
            time.sleep(0.005)
            continue
        answered.extend(results)
        windows += 1
        if windows % 5 == 1:
            p95 = np.percentile([r.latency_s for r in answered], 95)
            print(f"  window {windows}: {len(results)} queries @ snapshot "
                  f"epoch {results[0].version.epoch} "
                  f"(p95 so far {p95*1e3:.1f} ms)")
    thread.join()

    # after-the-fact audit: replay the stream on a single store and check
    # every k-hop answer at the version it was served from
    g = DynamicGraph(n, e_max)
    for b in batches:
        g.apply(b)
    checked = 0
    for r in answered:
        if isinstance(r.query, KHop):
            view = g.join_view(r.version)
            expect = np.asarray(gc.k_hop(view, np.array([r.query.source]),
                                         r.query.k))
            assert np.array_equal(r.value, expect), \
                f"divergence at {r.version} for {r.query}"
            checked += 1
    s = server.stats()
    print(f"\nserved {s.served} queries in {windows} windows while "
          f"ingesting; {checked} k-hop answers audited byte-identical "
          "against the single store")
    print(f"  p50={s.query_p50_s*1e3:.2f}ms  p95={s.query_p95_s*1e3:.2f}ms")
    print(f"  vectorized calls: {s.vectorized_calls}")
    print(f"  pagerank: {s.rank_warm_starts} warm starts / "
          f"{s.rank_cold_starts} cold, {s.rank_cache_hits} cache hits")
    print(f"  bounded caches: {s.cached_stitched_views} stitched views, "
          f"{s.cached_rank_versions} rank versions")
    print("\nOK — online queries served on live sharded snapshots")


if __name__ == "__main__":
    main()
