"""SH003 clean twin: bit layout stays behind Version.unpack; left-shift
key packing (edge keys, grouping keys) is legitimate and untouched."""
import numpy as np

from repro.core.versioned import Version


def epoch_of(packed: int) -> int:
    return Version.unpack(packed).epoch


def is_sealed(log, frontier):
    return [Version.unpack(v).epoch <= frontier for v in log]


def edge_keys(src, dst):
    # '<< 32' packing is fine — the rule only owns the unpack direction
    return (dst.astype(np.int64) << 32) | src.astype(np.int64)
