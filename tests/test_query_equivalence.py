"""Online-query equivalence: stitched ShardedDynamicGraph views vs the
loop-based single-store oracle.

Every query the serving layer vectorizes — k-hop, reachability (scalar and
multi-source frontier), degree top-k, incremental (warm-started) PageRank —
must be byte-identical when run on the stitched sharded view and on a view
built from the oracle's CSR arrays, at shard counts {1, 2, 4}, including
queries issued mid-stream against the frontier snapshot while a newer
epoch is still ingesting.
"""
import numpy as np
import pytest

from repro.core.versioned import Version
from repro.graph import compute as gc
from repro.graph.dyngraph import build_join_view, synthesize_churn_stream
from repro.graph.reference import LoopDynamicGraph
from repro.graph.sharded import ShardedDynamicGraph


def oracle_view(ref: LoopDynamicGraph, version: Version):
    """JoinView assembled from the loop oracle's CSR arrays."""
    offsets, src, dst, out_deg, in_deg = ref.join_view_arrays(version)
    keys = (dst.astype(np.int64) << 32) | src.astype(np.int64)
    return build_join_view(version, ref.n_max, keys, src, dst,
                           in_deg, out_deg)


def _stream(n, epochs, adds, seed):
    return synthesize_churn_stream(n, epochs, adds, seed=seed,
                                   delete_frac=0.25, readd_frac=0.3)


@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_khop_matches_oracle(n_shards):
    n, epochs = 48, 5
    batches = _stream(n, epochs, 60, seed=21)
    sg = ShardedDynamicGraph(n_shards, n, 4096)
    ref = LoopDynamicGraph(n, 4096)
    for b in batches:
        sg.apply(b)
        ref.apply(b)
    sources = np.array([0, 3, 17, 41], np.int32)
    for e in range(epochs):
        v = Version(e, 0)
        sv, ov = sg.join_view(v), oracle_view(ref, v)
        for k in (1, 2, 3):
            got = np.asarray(gc.batched_k_hop(sv, sources, k))
            for row, s in enumerate(sources):
                exp = np.asarray(gc.k_hop(ov, np.array([s]), k))
                np.testing.assert_array_equal(got[row], exp)


@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_reachability_matches_oracle(n_shards):
    n, epochs = 48, 5
    batches = _stream(n, epochs, 60, seed=22)
    sg = ShardedDynamicGraph(n_shards, n, 4096)
    ref = LoopDynamicGraph(n, 4096)
    for b in batches:
        sg.apply(b)
        ref.apply(b)
    rng = np.random.default_rng(5)
    srcs = rng.integers(0, n, 12).astype(np.int32)
    dsts = rng.integers(0, n, 12).astype(np.int32)
    for e in (0, epochs - 1):
        v = Version(e, 0)
        sv, ov = sg.join_view(v), oracle_view(ref, v)
        # 0 is falsy = unbounded on BOTH entry points (scalar promotes it)
        for max_hops in (0, 2, None):
            got = np.asarray(gc.batched_reachability(sv, srcs, dsts,
                                                     max_hops))
            exp = [gc.reachability(ov, int(s), int(d), max_hops)
                   for s, d in zip(srcs, dsts, strict=True)]
            assert got.tolist() == exp


@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_incremental_pagerank_matches_oracle(n_shards):
    """The warm-start chain over stitched sharded views is bitwise equal to
    the same chain over oracle views (identical CSRs -> identical op
    sequence), and degree top-k agrees."""
    n, epochs = 40, 4
    batches = _stream(n, epochs, 50, seed=23)
    sg = ShardedDynamicGraph(n_shards, n, 4096)
    ref = LoopDynamicGraph(n, 4096)
    for b in batches:
        sg.apply(b)
        ref.apply(b)
    prev_s = prev_o = None
    for e in range(epochs):
        v = Version(e, 0)
        sv, ov = sg.join_view(v), oracle_view(ref, v)
        if prev_s is None:
            rs = gc.pagerank(sv, tol=1e-10, max_iter=200)
            ro = gc.pagerank(ov, tol=1e-10, max_iter=200)
        else:
            rs = gc.incremental_pagerank(prev_s, None, sv, tol=1e-10,
                                         max_iter=200)
            ro = gc.incremental_pagerank(prev_o, None, ov, tol=1e-10,
                                         max_iter=200)
        assert rs.iterations == ro.iterations
        np.testing.assert_array_equal(np.asarray(rs.ranks),
                                      np.asarray(ro.ranks))
        ids_s, deg_s = gc.degree_topk(sv, 8)
        ids_o, deg_o = gc.degree_topk(ov, 8)
        np.testing.assert_array_equal(np.asarray(ids_s), np.asarray(ids_o))
        np.testing.assert_array_equal(np.asarray(deg_s), np.asarray(deg_o))
        prev_s, prev_o = rs, ro


@pytest.mark.parametrize("n_shards", [2, 4])
def test_midstream_frontier_queries_match_oracle(n_shards):
    """Queries issued against the frontier snapshot while a NEWER epoch is
    mid-ingest (dispatched, some shards sealed, frontier held back) answer
    from the last consistent snapshot and match the oracle at that
    version."""
    n, epochs = 40, 4
    batches = _stream(n, epochs, 50, seed=24)
    sg = ShardedDynamicGraph(n_shards, n, 4096)
    ref = LoopDynamicGraph(n, 4096)
    for b in batches[:-1]:
        sg.apply(b)
        ref.apply(b)
    # last epoch: dispatch + seal all shards but shard 0 — frontier holds
    last = batches[-1]
    sg.ingest(last)
    for shard in range(1, n_shards):
        sg.seal_shard(shard, last.version.epoch)
    v_frontier = sg.latest_sealed()
    assert v_frontier == batches[-2].version
    sv, ov = sg.join_view(v_frontier), oracle_view(ref, v_frontier)
    sources = np.array([1, 7, 13], np.int32)
    got = np.asarray(gc.batched_k_hop(sv, sources, 2))
    for row, s in enumerate(sources):
        np.testing.assert_array_equal(
            got[row], np.asarray(gc.k_hop(ov, np.array([s]), 2)))
    # straggler catches up: the new frontier snapshot matches the oracle
    # with the last batch applied
    sg.seal_shard(0, last.version.epoch)
    ref.apply(last)
    assert sg.latest_sealed() == last.version
    sv2, ov2 = sg.join_view(last.version), oracle_view(ref, last.version)
    got2 = np.asarray(gc.batched_k_hop(sv2, sources, 2))
    for row, s in enumerate(sources):
        np.testing.assert_array_equal(
            got2[row], np.asarray(gc.k_hop(ov2, np.array([s]), 2)))
