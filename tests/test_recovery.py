"""Durable graph plane: WAL framing, crash recovery, fault injection,
degraded serving, and the retrying RPC client.

The contracts under test, per the "Durability & recovery" section of
``docs/ARCHITECTURE.md``:

* the WAL record framing round-trips payload rows byte-identically, and
  the committed fixture corpus pins the on-disk format: a torn tail is
  truncated with a warning, while mid-segment corruption (bad CRC,
  unframeable length prefix, trailing bytes in a closed segment) is a
  typed :class:`WalCorruptionError` naming segment + byte offset,
* recovery equals replay: a store recovered from checkpoint + WAL tail is
  byte-identical to an uncrashed oracle at EVERY sealed version — across
  shard counts, checkpoint cadences, and split/merge cutovers — and keeps
  ingesting identically afterwards,
* with batched fsync a crash loses only the unsynced suffix: recovery
  lands at the durable frontier, truncates the dead tail, and re-driving
  the lost epochs converges with the oracle,
* checkpoint saves are crash-atomic: an interrupted save (data file or
  manifest) leaves the previous checkpoint fully restorable,
* the serving tier degrades instead of dying: an injected shard fault
  holds the published snapshot, stamps responses ``degraded``, surfaces
  ``stale_epochs``/``seal_failures`` in stats, and catches up after heal,
* the RPC client retries ``ERR_OVERLOADED`` and transport faults with
  capped exponential backoff + jitter, honors the deadline as a total
  budget, surfaces the ORIGINAL typed response on give-up, and never
  retries non-retryable typed errors.
"""
import os
import pathlib
import shutil
import socket
import threading
import time

import numpy as np
import pytest

from repro.core.versioned import Version
from repro.graph import compute as gc
from repro.graph.dyngraph import synthesize_churn_stream
from repro.graph.query import (ERR_BAD_QUERY, ERR_OVERLOADED, KHop,
                               QueryRequest, QueryResponse)
from repro.graph.sharded import ShardedDynamicGraph, encode_payload_rows
from repro.graph.wal import (FaultInjector, GraphCheckpointManager,
                             GraphWal, ShardFaultError, ShardWal,
                             WalCorruptionError, encode_record,
                             rows_to_body, scan_segment,
                             scan_shard_records, truncate_shard_after)
from repro.launch import rpc
from repro.launch.serve_graph import GraphQueryServer

FIXTURES = pathlib.Path(__file__).parent / "wal_fixtures"


# ----------------------------------------------------------- test helpers
def _stream(n, epochs, adds, seed=13):
    batches = synthesize_churn_stream(n, epochs, adds, seed=seed,
                                      delete_frac=0.2)
    e_max = sum(len(b.add_src) for b in batches) * 2 + 64
    return batches, e_max


def _assert_same_view(a, b, ctx=""):
    for field in ("offsets", "src", "dst", "out_degree", "in_degree"):
        got = np.asarray(getattr(a, field))
        want = np.asarray(getattr(b, field))
        assert got.dtype == want.dtype, (ctx, field)
        assert np.array_equal(got, want), (ctx, field)


def _assert_equiv(recovered, oracle, batches, *, check_latest=True):
    """Byte-identical joined views at EVERY sealed version. With
    ``check_latest=False`` the oracle may be ahead (the recovered store
    lost an unsynced suffix it has not re-driven yet)."""
    for b in batches:
        _assert_same_view(recovered.join_view(b.version),
                          oracle.join_view(b.version),
                          ctx=f"epoch {b.version.epoch}")
    if check_latest:
        assert recovered.latest_sealed() == oracle.latest_sealed()


# ------------------------------------------------------------ WAL framing
def test_record_codec_round_trips_byte_identical():
    rng = np.random.default_rng(7)
    for n in (0, 1, 17, 256):
        rows = rng.integers(-(2**31), 2**31 - 1, size=(n, 4),
                            dtype=np.int64).astype(np.int32)
        packed = Version(int(rng.integers(0, 1000)), 0).pack()
        framed = encode_record(packed, rows_to_body(rows))
        path = None
        # scan from bytes via a temp file
        import tempfile
        with tempfile.NamedTemporaryFile(suffix=".wal",
                                         delete=False) as f:
            f.write(framed)
            path = f.name
        try:
            [(got_packed, body, off)], clean = scan_segment(path)
            assert got_packed == packed and off == 0
            assert clean == len(framed)
            got = np.frombuffer(body, "<i4").reshape(-1, 4)
            assert np.array_equal(got, rows)
        finally:
            os.unlink(path)


def test_fixture_corpus_matches_generator(tmp_path):
    """The committed fixtures are exactly what the generator emits — a
    framing change must fail here loudly, never silently re-bless."""
    import sys
    sys.path.insert(0, str(FIXTURES))
    try:
        from make_fixtures import write_fixtures
    finally:
        sys.path.pop(0)
    fresh = write_fixtures(tmp_path)
    assert fresh, "generator produced nothing"
    for name, data in fresh.items():
        committed = (FIXTURES / name).read_bytes()
        assert committed == data, f"fixture {name} drifted from generator"


def test_fixture_interleaved_scans_clean():
    records, clean = scan_segment(FIXTURES / "interleaved.wal")
    assert [Version.unpack(p).epoch for p, _, _ in records] == [0, 1, 2, 3]
    assert records[2][1] == b""                 # empty epoch's record
    assert clean == (FIXTURES / "interleaved.wal").stat().st_size


def test_fixture_torn_tail_truncates_and_warns(tmp_path):
    with pytest.warns(UserWarning, match="torn WAL tail"):
        records, clean = scan_segment(FIXTURES / "torn_tail.wal")
    assert [Version.unpack(p).epoch for p, _, _ in records] == [0, 1]
    assert clean < (FIXTURES / "torn_tail.wal").stat().st_size
    # as a shard segment the torn record simply is not an epoch yet
    d = tmp_path / "shard"
    d.mkdir()
    shutil.copy(FIXTURES / "torn_tail.wal", d / "seg-00000000.wal")
    with pytest.warns(UserWarning, match="torn WAL tail"):
        by_epoch = scan_shard_records(d)
    assert sorted(by_epoch) == [0, 1]


def test_fixture_truncated_prefix_is_tail_only_for_open_segment():
    with pytest.warns(UserWarning, match="dropping 7 bytes"):
        records, clean = scan_segment(FIXTURES / "truncated_prefix.wal")
    assert len(records) == 1 and clean == 96
    # a CLOSED segment (rotation ends on a record boundary) may not carry
    # a tail at all: same bytes, typed corruption
    with pytest.raises(WalCorruptionError, match="trailing bytes"):
        scan_segment(FIXTURES / "truncated_prefix.wal", tail_ok=False)


@pytest.mark.parametrize("name,reason", [
    ("bad_crc.wal", "CRC mismatch"),
    ("bad_length.wal", "length prefix"),
])
def test_fixture_corruption_raises_typed_with_location(name, reason):
    with pytest.raises(WalCorruptionError, match=reason) as ei:
        scan_segment(FIXTURES / name)
    err = ei.value
    assert err.segment.endswith(name)
    assert err.offset == 96                     # after the first record
    assert f"@ byte {err.offset}" in str(err)


def test_shard_wal_rotation_gc_and_truncation(tmp_path):
    w = ShardWal(tmp_path, 0, fsync="never")
    rows = lambda e: np.full((2, 4), e, np.int32)           # noqa: E731
    for e in range(3):
        w.append(e, rows(e))
    w.rotate(3)
    for e in range(3, 6):
        w.append(e, rows(e))
    w.close()
    assert [p.name for p in w.segments()] == ["seg-00000000.wal",
                                              "seg-00000003.wal"]
    assert sorted(scan_shard_records(tmp_path)) == list(range(6))
    # checkpoint landed at epoch 2: the first segment is dead weight
    assert w.drop_segments_below(3) == 1
    assert sorted(scan_shard_records(tmp_path)) == [3, 4, 5]
    # recovery truncates uncommitted records so re-seals append cleanly
    assert truncate_shard_after(tmp_path, 4) == 1
    assert sorted(scan_shard_records(tmp_path)) == [3, 4]
    assert truncate_shard_after(tmp_path, 4) == 0           # idempotent


# --------------------------------------------------- checkpoint atomicity
def _small_store(batches, e_max, n, **kw):
    sg = ShardedDynamicGraph(2, n, e_max, **kw)
    for b in batches:
        sg.apply(b)
    return sg


@pytest.mark.parametrize("victim", ["ckpt_", "MANIFEST.json"])
def test_interrupted_checkpoint_save_keeps_previous(tmp_path, monkeypatch,
                                                    victim):
    """Kill the save at either ``os.replace`` (data file or manifest):
    the previous checkpoint must stay fully loadable either way."""
    n = 64
    batches, e_max = _stream(n, 4, 40)
    sg = _small_store(batches[:2], e_max, n)
    mgr = GraphCheckpointManager(tmp_path, keep=3)
    mgr.save_graph(sg, epoch=1)
    before = GraphCheckpointManager(tmp_path, keep=3).load_graph()
    assert before is not None and before["epoch"] == 1

    for b in batches[2:]:
        sg.apply(b)
    real = os.replace

    def boom(src, dst, *a, **kw):
        if victim in pathlib.Path(dst).name:
            raise OSError("simulated crash mid-save")
        return real(src, dst, *a, **kw)

    monkeypatch.setattr(os, "replace", boom)
    with pytest.raises(OSError, match="simulated crash"):
        mgr.save_graph(sg, epoch=3)
    monkeypatch.setattr(os, "replace", real)

    fresh = GraphCheckpointManager(tmp_path, keep=3)
    got = fresh.load_graph()
    if victim == "ckpt_":
        # data file never landed: index still serves epoch 1
        assert got["epoch"] == 1
    else:
        # data landed but the manifest did not: the unlisted .npz is
        # invisible (a later save's GC sweeps it) — epoch 1 still serves
        assert got["epoch"] == 1
    for k, arr in got["shards"][0].items():
        assert np.array_equal(arr, before["shards"][0][k]), k


# ------------------------------------------------- crash recovery: oracle
@pytest.mark.parametrize("n_shards", [1, 2, 4])
@pytest.mark.parametrize("checkpoint_every", [0, 3])
def test_recover_equals_oracle_at_every_version(tmp_path, n_shards,
                                                checkpoint_every):
    n, epochs = 96, 10
    batches, e_max = _stream(n, epochs, 50)
    sg = ShardedDynamicGraph(n_shards, n, e_max, wal_dir=tmp_path,
                             wal_fsync="always",
                             checkpoint_every=checkpoint_every)
    for b in batches[:8]:
        sg.apply(b)
    # crash: the object is abandoned; "always" fsync made every record
    # durable, so recovery must land on the full frontier
    rec = ShardedDynamicGraph.recover(tmp_path)
    assert rec.coordinator.global_frontier == 7

    oracle = ShardedDynamicGraph(n_shards, n, e_max)
    for b in batches[:8]:
        oracle.apply(b)
    _assert_equiv(rec, oracle, batches[:8])

    # the recovered store is a first-class store: keep ingesting
    for b in batches[8:]:
        rec.apply(b)
        oracle.apply(b)
    _assert_equiv(rec, oracle, batches)
    del sg


def test_recover_across_split_and_merge_cutovers(tmp_path):
    n, epochs = 96, 9
    batches, e_max = _stream(n, epochs, 60, seed=5)
    sg = ShardedDynamicGraph(2, n, e_max, wal_dir=tmp_path,
                             wal_fsync="always", checkpoint_every=4)
    oracle = ShardedDynamicGraph(2, n, e_max)
    for b in batches[:3]:
        sg.apply(b)
        oracle.apply(b)
    sg.split_shard(0)
    oracle.split_shard(0)
    for b in batches[3:6]:
        sg.apply(b)
        oracle.apply(b)
    sg.merge_shards(2)
    oracle.merge_shards(2)
    for b in batches[6:]:
        sg.apply(b)
        oracle.apply(b)

    rec = ShardedDynamicGraph.recover(tmp_path)
    assert rec.plan.history == oracle.plan.history
    assert rec.retired == oracle.retired
    assert rec.coordinator.global_frontier == epochs - 1
    _assert_equiv(rec, oracle, batches)
    # per-shard arrays, not just views, must be byte-identical
    for s_rec, s_ora in zip(rec.shards, oracle.shards, strict=True):
        e = s_ora.n_edges
        assert s_rec.n_edges == e
        for f in ("src", "dst", "created", "deleted"):
            assert np.array_equal(getattr(s_rec, f)[:e],
                                  getattr(s_ora, f)[:e]), f
        assert np.array_equal(s_rec.v_created, s_ora.v_created)

    more, _ = _stream(n, epochs + 2, 60, seed=5)
    for b in more[epochs:]:
        rec.apply(b)
        oracle.apply(b)
    _assert_equiv(rec, oracle, more)
    del sg


def test_batch_fsync_crash_recovers_at_durable_frontier(tmp_path):
    """With batched fsync the unsynced suffix dies with the process; the
    durable frontier is still well defined, the dead tail is truncated,
    and re-driving the lost epochs converges with the oracle."""
    n, epochs = 96, 10
    batches, e_max = _stream(n, epochs, 50, seed=3)
    sg = ShardedDynamicGraph(2, n, e_max, wal_dir=tmp_path,
                             wal_fsync="batch", wal_fsync_every=64,
                             checkpoint_every=4)
    for b in batches:
        sg.apply(b)
    # keep `sg` alive: its unflushed python-level buffers must NOT reach
    # disk (a real crash would lose them), which del/GC would flush
    rec = ShardedDynamicGraph.recover(tmp_path)
    frontier = rec.coordinator.global_frontier
    # checkpoints fsync the WAL when they land, so the ladder's last rung
    # bounds the loss; the unsynced suffix may or may not have made it
    assert 7 <= frontier <= epochs - 1

    # a second recovery from the (now truncated) log is a no-op replay
    rec2 = ShardedDynamicGraph.recover(tmp_path)
    assert rec2.coordinator.global_frontier == frontier

    oracle = ShardedDynamicGraph(2, n, e_max)
    for b in batches:
        oracle.apply(b)
    _assert_equiv(rec, oracle, batches[:frontier + 1], check_latest=False)
    for b in batches[frontier + 1:]:            # re-drive the lost tail
        rec.apply(b)
    _assert_equiv(rec, oracle, batches)
    del sg


def test_recover_survives_torn_shard_tail(tmp_path):
    n = 64
    batches, e_max = _stream(n, 6, 40, seed=9)
    sg = ShardedDynamicGraph(2, n, e_max, wal_dir=tmp_path,
                             wal_fsync="always")
    for b in batches:
        sg.apply(b)
    # simulate a mid-append crash: half a record at the end of shard 0
    seg = sorted(GraphWal.shard_dir(tmp_path, 0).glob("seg-*.wal"))[-1]
    with open(seg, "ab") as f:
        f.write(b"\x00\x01\x02\x03\x04\x05\x06")
    with pytest.warns(UserWarning, match="torn WAL tail"):
        rec = ShardedDynamicGraph.recover(tmp_path)
    assert rec.coordinator.global_frontier == 5
    oracle = ShardedDynamicGraph(2, n, e_max)
    for b in batches:
        oracle.apply(b)
    _assert_equiv(rec, oracle, batches)
    del sg


def test_recover_refuses_mid_segment_corruption(tmp_path):
    n = 64
    batches, e_max = _stream(n, 5, 40, seed=9)
    sg = ShardedDynamicGraph(2, n, e_max, wal_dir=tmp_path,
                             wal_fsync="always")
    for b in batches:
        sg.apply(b)
    seg = sorted(GraphWal.shard_dir(tmp_path, 1).glob("seg-*.wal"))[0]
    data = bytearray(seg.read_bytes())
    data[20] ^= 0xFF                            # flip a body byte
    seg.write_bytes(bytes(data))
    with pytest.raises(WalCorruptionError, match="CRC mismatch") as ei:
        ShardedDynamicGraph.recover(tmp_path)
    assert ei.value.segment.endswith(seg.name)
    del sg


def test_payload_reencode_is_replay_stable():
    """The seal's WAL record re-encodes the merged batches; decode of
    that encoding must reproduce the payload fields exactly (this is the
    byte-stability recovery leans on)."""
    from repro.graph.sharded import decode_payloads
    batches, _ = _stream(48, 3, 30, seed=21)
    for b in batches:
        rows = encode_payload_rows(b)
        # a WAL record's body is exactly these rows; decode + re-encode
        # must be the identity on the byte-stable row form
        [back] = decode_payloads([rows])
        assert np.array_equal(encode_payload_rows(back), rows)


# -------------------------------------------------------- degraded serving
def _served_store(n=128, epochs=3, adds=60, **kw):
    batches, e_max = _stream(n, epochs + 4, adds, seed=11)
    inj = FaultInjector()
    sg = ShardedDynamicGraph(2, n, e_max, fault_injector=inj, **kw)
    srv = GraphQueryServer(sg, auto_reshard=False, prewarm_traces=False)
    for b in batches[:epochs]:
        srv.step(b)
    return srv, sg, inj, batches


def test_fault_injector_kills_seal_cleanly_and_reseals():
    srv, sg, inj, batches = _served_store()
    inj.fail(1)                                 # one-shot
    sg.ingest(batches[3])
    with pytest.raises(ShardFaultError, match="shard 1"):
        sg.seal_epoch(3)
    assert inj.faults_fired == 1
    assert sg.coordinator.global_frontier == 2  # frontier held (I6)
    assert sg.seal_epoch(3) == 3                # one-shot: re-seal works


def test_server_degrades_and_catches_up_matching_oracle():
    srv, sg, inj, batches = _served_store()
    n = 128
    healthy = srv.query(KHop(5, k=1))
    assert healthy.version.epoch == 2

    inj.drop(1)
    srv.step(batches[3])                        # absorbed, not raised
    srv.step(batches[4])
    s = srv.stats()
    assert s.degraded and s.seal_failures == 2 and s.stale_epochs == 2
    r = srv.query(KHop(5, k=1))
    assert r.version.epoch == 2                 # last published snapshot
    # the degraded hint rides on every response in the window
    got = {}
    done = threading.Event()
    srv.submit_request(
        QueryRequest(query=KHop(5, k=1), request_id="x"),
        on_done=lambda resp: (got.update(r=resp), done.set()))
    srv.run_window()
    assert done.wait(1.0) and got["r"].degraded

    inj.heal()
    assert srv.reseal() == 4                    # catch-up through backlog
    s = srv.stats()
    assert not s.degraded and s.stale_epochs == 0
    assert s.seal_failures == 2                 # monotone counter
    r = srv.query(KHop(9, k=2))
    assert r.version.epoch == 4
    oracle = ShardedDynamicGraph(2, n, 100_000)
    for b in batches[:5]:
        oracle.apply(b)
    expect = np.asarray(gc.k_hop(oracle.join_view(batches[4].version),
                                 np.array([9]), 2))
    assert np.asarray(r.value).tobytes() == expect.tobytes()


def test_degraded_flag_round_trips_the_wire():
    ok = QueryResponse.answered(1, np.arange(3), Version(2, 0), 0.1,
                                degraded=True)
    frame = rpc.encode_response(ok)
    assert frame["degraded"] is True
    assert rpc.decode_response(frame).degraded
    healthy = QueryResponse.answered(1, np.arange(3), Version(2, 0), 0.1)
    frame = rpc.encode_response(healthy)
    assert "degraded" not in frame              # absent = healthy default
    assert not rpc.decode_response(frame).degraded


# ------------------------------------------------------- RPC retry client
class _ScriptedFront:
    """Raw-socket stand-in for the RPC server that answers each request
    per a fixed script — retry behavior becomes deterministic, no timing
    luck. Actions: ``shed`` (typed overload), ``ok``, ``bad_query``,
    ``drop`` (close the connection without replying)."""

    def __init__(self, script):
        self.script = list(script)
        self.requests = 0
        self.frames: list[dict] = []
        self._sock = socket.create_server(("127.0.0.1", 0))
        self._sock.settimeout(0.2)
        self._stop = threading.Event()
        self._t = threading.Thread(target=self._loop, daemon=True)
        self._t.start()

    @property
    def address(self):
        return self._sock.getsockname()[:2]

    def _loop(self):
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            with conn:
                self._serve(conn)

    def _serve(self, conn):
        while not self._stop.is_set():
            try:
                frame = rpc.read_frame(conn)
            except (ConnectionError, OSError):
                return
            if frame is None:
                return
            act = self.script[self.requests] \
                if self.requests < len(self.script) else "ok"
            self.requests += 1
            self.frames.append(frame)
            rid = frame.get("id", 0)
            if act == "drop":
                return                          # EOF mid-round-trip
            if act == "shed":
                resp = QueryResponse.failed(rid, ERR_OVERLOADED, "shed")
            elif act == "bad_query":
                resp = QueryResponse.failed(rid, ERR_BAD_QUERY, "nope")
            else:
                resp = QueryResponse.answered(rid, np.arange(3),
                                              Version(1, 0), 0.0)
            try:
                conn.sendall(rpc.encode_frame(rpc.encode_response(resp)))
            except OSError:
                return

    def stop(self):
        self._stop.set()
        self._sock.close()
        self._t.join(timeout=2.0)


@pytest.fixture
def scripted():
    fronts = []

    def make(script, **kw):
        front = _ScriptedFront(script)
        kw.setdefault("retry_base_s", 0.002)
        client = rpc.GraphRPCClient(*front.address, **kw)
        fronts.append((front, client))
        return front, client

    yield make
    for front, client in fronts:
        client.close()
        front.stop()


def test_backoff_is_exponential_capped_and_half_jittered(scripted):
    _, c = scripted(["ok"], retry_cap_s=0.5, jitter=lambda: 0.0)
    base = c.retry_base_s
    assert c._backoff(0) == base * 0.5          # jitter floor: b/2
    assert c._backoff(1) == base * 2 * 0.5
    assert c._backoff(30) == 0.5 * 0.5          # capped at retry_cap_s
    c._jitter = lambda: 1.0
    assert c._backoff(0) == base                # jitter ceiling: b
    assert c._backoff(30) == 0.5


def test_overloaded_is_retried_until_success(scripted):
    front, c = scripted(["shed", "shed", "ok"], jitter=lambda: 1.0)
    r = c.query(KHop(0, k=1))
    assert r.ok and front.requests == 3


def test_give_up_returns_the_original_typed_shed(scripted):
    front, c = scripted(["shed"] * 10, max_retries=2, jitter=lambda: 0.0)
    r = c.query(KHop(0, k=1))
    assert not r.ok and r.error.code == ERR_OVERLOADED
    assert front.requests == 3                  # 1 try + 2 retries


def test_deadline_is_a_total_budget_never_slept_past(scripted):
    front, c = scripted(["shed"] * 10, retry_base_s=1.0, max_retries=5,
                        jitter=lambda: 1.0)
    t0 = time.monotonic()
    r = c.query(KHop(0, k=1), deadline_s=0.05)
    elapsed = time.monotonic() - t0
    assert not r.ok and r.error.code == ERR_OVERLOADED
    assert elapsed < 0.5                        # gave up, did not sleep 1s
    assert front.requests == 1
    # each attempt ships the REMAINING budget to the server
    assert front.frames[0]["deadline_s"] <= 0.05


def test_non_retryable_typed_errors_return_immediately(scripted):
    front, c = scripted(["bad_query", "ok"], jitter=lambda: 1.0)
    r = c.query(KHop(0, k=1))
    assert not r.ok and r.error.code == ERR_BAD_QUERY
    assert front.requests == 1


def test_transport_eof_reconnects_and_replays(scripted):
    front, c = scripted(["drop", "ok"], jitter=lambda: 0.0)
    r = c.query(KHop(0, k=1))
    assert r.ok and front.requests == 2         # at-least-once replay


def test_transport_fault_exhaustion_reraises(scripted):
    front, c = scripted(["drop"] * 10, max_retries=1, jitter=lambda: 0.0)
    with pytest.raises((ConnectionError, OSError)):
        c.query(KHop(0, k=1))
    assert front.requests == 2


# ------------------------------------------------------------- chaos soak
@pytest.mark.chaos
def test_chaos_faults_wal_and_recovery_match_oracle(tmp_path):
    """The acceptance chaos run, shrunk to seconds: a WAL-backed server
    absorbs a seeded schedule of one-shot kills and a drop/heal outage
    while ingesting, reseals to catch up, ends byte-identical to the
    oracle — and a post-hoc recovery from its WAL agrees too."""
    n, epochs = 128, 10
    batches, e_max = _stream(n, epochs, 60, seed=17)
    inj = FaultInjector()
    sg = ShardedDynamicGraph(2, n, e_max, wal_dir=tmp_path,
                             wal_fsync="always", checkpoint_every=4,
                             fault_injector=inj)
    srv = GraphQueryServer(sg, auto_reshard=False, prewarm_traces=False)
    rng = np.random.default_rng(17)
    outage_at, heal_at = 4, 6
    for e, b in enumerate(batches):
        if e in (2, 7):
            inj.fail(int(rng.integers(0, 2)))   # one-shot kill
        if e == outage_at:
            inj.drop(1)
        if e == heal_at:
            inj.heal()
            srv.reseal()
        srv.step(b)
        if e < outage_at or e >= heal_at:
            srv.reseal()                        # catch up after one-shots
    srv.reseal()
    assert srv.stats().seal_failures >= 3
    assert not srv.stats().degraded
    assert sg.coordinator.global_frontier == epochs - 1

    oracle = ShardedDynamicGraph(2, n, e_max)
    for b in batches:
        oracle.apply(b)
    _assert_equiv(sg, oracle, batches)
    inj.heal()
    rec = ShardedDynamicGraph.recover(tmp_path)
    _assert_equiv(rec, oracle, batches)
